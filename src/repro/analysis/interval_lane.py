"""Abstract-interpretation lane: the :class:`~repro.core.lanes.Lane`
protocol over symbolic per-element interval bounds.

Running any lane-generic program (both attention mechanisms, the PTQ'd
layers, the whole-model forward) on an :class:`IntervalLane` executes *no
concrete data* — handles are :class:`~repro.analysis.interval.
IntervalTensor` bounds — yet produces the exact same static op trace the
``fhe_sim`` lane measures: op counts are shape-determined and shapes are
concrete, so PBS / cmul / add / lit-mul counters agree *exactly* with a
measured forward, while every message-width observation is the proven
worst case over all inputs in the declared quantized ranges.

Soundness contract (tested in tests/test_analysis.py): for any concrete
input whose elements lie inside the ingested intervals, an ``fhe_sim``
forward of the same program observes, in every scope, per-op counts equal
to — and ``max_bits_at_pbs`` / ``max_bits_any`` dominated by — this lane's
static trace.  The mechanics:

  * cost accounting reuses :class:`repro.fhe.tfhe_sim.FheContext`
    verbatim — counters receive zero-copy broadcast "magnitude proxies"
    (an array of the interval's worst absolute value in the op's shape),
    so the width bookkeeping (signed-bit formula, at-PBS vs anywhere,
    scope attribution) is the measured lane's own code path;
  * every cipher×cipher multiply records a **cmul site** (scope, op,
    count, PBS width of the packed a±b operands) — the inhibitor family's
    zero-cmul claim becomes checkable as ``cmul_sites == []``;
  * every LUT records a **site report** (declared domain, raw input
    interval, saturation margins, required table width) — parameter
    selection and the LUT-domain verification gate read these.

Control flow in lane-generic programs never branches on ciphertext values
(TFHE could not execute it if it did), so one abstract trace covers every
input of the given shape/config — that is what turns "zero cmuls observed"
into "zero cmuls, proven".
"""

from __future__ import annotations

import contextlib
from typing import List, Optional

import numpy as np

from repro.analysis.interval import (MAX_LUT_DOMAIN, IntervalTensor,
                                     as_interval, broadcast_interval,
                                     literal_mul_bounds, matmul_plain_bounds,
                                     mul_bounds, table_range_minmax)
from repro.core.lanes import _MASKED_ROW, Lane

_SENTINEL_MIN = np.iinfo(np.int64).min


class IntervalLane(Lane):
    """Static-analysis lane: interval transfer functions + the measured
    lane's own cost accounting over magnitude proxies."""

    name = "interval"

    def __init__(self, ctx=None):
        from repro.fhe.tfhe_sim import FheContext

        self.ctx = ctx if ctx is not None else FheContext()
        #: cipher×cipher sites: {scope, op, count, pbs_bits}
        self.cmul_sites: List[dict] = []
        #: LUT sites: {scope, domain, input, saturated, table_bits, fits}
        self.lut_sites: List[dict] = []
        #: per-scope proven value ranges [lo, hi] over every intermediate
        self.value_ranges: dict = {}
        self._scope: Optional[str] = None
        self._op: Optional[str] = None   # contraction label for cmul sites

    # ---- bookkeeping helpers -------------------------------------------
    def _proxy(self, t: IntervalTensor) -> np.ndarray:
        """Zero-copy magnitude proxy: worst |value| broadcast to the op's
        shape, so FheContext sees the right element count AND the proven
        worst-case width through its unmodified counting API."""
        return np.broadcast_to(np.int64(t.max_abs()), t.shape)

    def _note(self, t: IntervalTensor) -> IntervalTensor:
        """Record the interval into the active scope's value range."""
        scope = self._scope or "<root>"
        lo, hi = t.extremes()
        cur = self.value_ranges.get(scope)
        if cur is None:
            self.value_ranges[scope] = [lo, hi]
        else:
            cur[0] = min(cur[0], lo)
            cur[1] = max(cur[1], hi)
        return t

    # ---- ingest / export ------------------------------------------------
    def array(self, x):
        return as_interval(x)

    def embed(self, table: np.ndarray, tokens):
        """Symbolic client-side embedding: the analysis must hold for ANY
        token sequence of this shape, so each channel's interval spans the
        whole vocabulary's quantized rows; ``tokens`` contributes shape
        only (its values are never read)."""
        table = np.asarray(table, np.int64)
        shp = tuple(np.shape(tokens)) + table.shape[1:]
        lo = np.broadcast_to(table.min(axis=0), shp).copy()
        hi = np.broadcast_to(table.max(axis=0), shp).copy()
        return self._note(IntervalTensor(lo, hi, what="embed"))

    def to_numpy(self, t):
        raise TypeError(
            "IntervalLane handles are abstract bounds, not values; read "
            "handle.lo / handle.hi (or .extremes()) instead of to_numpy()")

    def shape(self, t):
        return t.shape

    # ---- structure ------------------------------------------------------
    def expand_dims(self, t, axis):
        return IntervalTensor(np.expand_dims(t.lo, axis),
                              np.expand_dims(t.hi, axis))

    def repeat(self, t, rep, axis):
        return IntervalTensor(np.repeat(t.lo, rep, axis=axis),
                              np.repeat(t.hi, rep, axis=axis))

    # reshape/transpose: base Lane delegates to the handle's methods

    # ---- levelled ops ---------------------------------------------------
    def add(self, a, b):
        b = as_interval(b)
        out = IntervalTensor(a.lo + b.lo, a.hi + b.hi, what="add")
        self.ctx.count_add(self._proxy(out))
        return self._note(out)

    def sub(self, a, b):
        b = as_interval(b)
        out = IntervalTensor(a.lo - b.hi, a.hi - b.lo, what="sub")
        self.ctx.count_add(self._proxy(out))
        return self._note(out)

    def neg(self, t):
        return IntervalTensor(-t.hi, -t.lo, what="neg")

    def mul_literal(self, t, c):
        out = literal_mul_bounds(t, c)
        self.ctx.count_lit_mul(self._proxy(out))
        return self._note(out)

    def shift_right(self, t, k):
        # arithmetic shift is monotone non-decreasing, endpoints map over
        out = IntervalTensor(t.lo >> k, t.hi >> k, what="shift_right")
        self.ctx.count_lit_mul(self._proxy(out))
        return self._note(out)

    def matmul_plain(self, t, w):
        w = np.asarray(w, np.int64)
        out = matmul_plain_bounds(t, w)
        n_vec = int(np.prod(t.shape[:-1], dtype=np.int64))
        d_in, d_out = w.shape
        self.ctx.count_lit_mul(self._proxy(out), n=n_vec * d_in * d_out)
        self.ctx.count_add(self._proxy(out),
                           n=n_vec * max(d_in - 1, 0) * d_out)
        return self._note(out)

    def sum(self, t, axis, keepdims=False):
        out = IntervalTensor(t.lo.sum(axis=axis, keepdims=keepdims),
                             t.hi.sum(axis=axis, keepdims=keepdims),
                             what="sum")
        self.ctx.count_add(self._proxy(out),
                           n=max(int(t.size - out.size), 0))
        return self._note(out)

    def select(self, mask, t, fill):
        m = np.asarray(mask, bool)
        fill = np.int64(fill)
        out = IntervalTensor(np.where(m, t.lo, fill),
                             np.where(m, t.hi, fill), what="select")
        self.ctx.count_lit_mul(self._proxy(out))
        return self._note(out)

    def clip(self, t, lo, hi):
        return IntervalTensor(np.clip(t.lo, lo, hi), np.clip(t.hi, lo, hi),
                              what="clip")

    # ---- PBS ops --------------------------------------------------------
    def relu(self, t):
        self.ctx.count_pbs(self._proxy(t))
        return self._note(IntervalTensor(np.maximum(t.lo, 0),
                                         np.maximum(t.hi, 0), what="relu"))

    def abs(self, t):
        self.ctx.count_pbs(self._proxy(t))
        alo, ahi = np.abs(t.lo), np.abs(t.hi)
        hi = np.maximum(alo, ahi)
        lo = np.where(t.lo > 0, t.lo, np.where(t.hi < 0, -t.hi, 0))
        return self._note(IntervalTensor(lo, hi, what="abs"))

    def max(self, t, axis, keepdims=False):
        self.ctx.count_pbs(self._proxy(t))
        return self._note(IntervalTensor(
            t.lo.max(axis=axis, keepdims=keepdims),
            t.hi.max(axis=axis, keepdims=keepdims), what="max"))

    def masked_max(self, t, mask, axis, keepdims=False):
        m = np.broadcast_to(np.asarray(mask, bool), t.shape)
        # mirror FheSimLane: the relu-tree covers attendable wires only
        self.ctx._bump("pbs", int(m.sum()))
        mag = np.where(m, np.maximum(np.abs(t.lo), np.abs(t.hi)), 0)
        self.ctx._observe(
            np.asarray([int(mag.max()) if mag.size else 0], np.int64),
            at_pbs=True)
        lo_m = np.where(m, t.lo, _SENTINEL_MIN)
        hi_m = np.where(m, t.hi, _SENTINEL_MIN)
        any_m = m.any(axis=axis, keepdims=keepdims)
        lo = np.where(any_m, lo_m.max(axis=axis, keepdims=keepdims),
                      np.int64(_MASKED_ROW))
        hi = np.where(any_m, hi_m.max(axis=axis, keepdims=keepdims),
                      np.int64(_MASKED_ROW))
        return self._note(IntervalTensor(lo, hi, what="masked_max"))

    def lut(self, t, fn, lo, hi, *, float_fn=None, int_fn=None):
        span = int(hi) - int(lo) + 1
        if span > MAX_LUT_DOMAIN:
            raise ValueError(
                f"LUT domain [{lo}, {hi}] has {span} entries — beyond the "
                f"analyzer's {MAX_LUT_DOMAIN}-entry materialization cap")
        cl = np.clip(t.lo, lo, hi)
        ch = np.clip(t.hi, lo, hi)
        sat = IntervalTensor(cl, ch, what="lut-input")
        # the PBS covers the *saturated* input — same width semantics as
        # FheSimLane.lut (which observes np.clip(t, lo, hi))
        self.ctx.count_pbs(self._proxy(sat))
        raw_lo, raw_hi = t.extremes()
        sat_lo, sat_hi = sat.extremes()
        table_bits = max(1, int(sat.max_abs()).bit_length()) + 1
        self.lut_sites.append({
            "scope": self._scope or "<root>",
            "domain": [int(lo), int(hi)],
            "input": [raw_lo, raw_hi],
            "saturated": [sat_lo, sat_hi],
            "overflow_lo": max(int(lo) - raw_lo, 0),
            "overflow_hi": max(raw_hi - int(hi), 0),
            "fits_domain": int(lo) <= raw_lo and raw_hi <= int(hi),
            "table_bits": table_bits,
        })
        domain = np.arange(lo, hi + 1, dtype=np.int64)
        tbl = np.asarray(fn(domain), dtype=np.int64)
        out_lo, out_hi = table_range_minmax(tbl, cl - lo, ch - lo)
        return self._note(IntervalTensor(out_lo, out_hi, what="lut"))

    # ---- ciphertext×ciphertext (dot-product arm only) -------------------
    def mul(self, a, b):
        s = IntervalTensor(a.lo + b.lo, a.hi + b.hi, what="cmul-pack")
        d = IntervalTensor(a.lo - b.hi, a.hi - b.lo, what="cmul-pack")
        self.ctx.count_cmul(self._proxy(s), self._proxy(d))
        self.cmul_sites.append({
            "scope": self._scope or "<root>",
            "op": self._op or "mul",
            "count": s.size,
            "pbs_bits": max(
                1, int(max(s.max_abs(), d.max_abs())).bit_length()) + 1,
        })
        out = mul_bounds(a, b, what="cipher-mul")
        self.ctx._observe(self._proxy(out), at_pbs=False)
        return self._note(out)

    def dot_scores(self, q, k):
        qe = IntervalTensor(q.lo[..., :, None, :], q.hi[..., :, None, :])
        ke = IntervalTensor(k.lo[..., None, :, :], k.hi[..., None, :, :])
        shp = np.broadcast_shapes(qe.shape, ke.shape)
        self._op = "dot_scores"
        try:
            prod = self.mul(broadcast_interval(qe, shp),
                            broadcast_interval(ke, shp))
        finally:
            self._op = None
        return self.sum(prod, axis=-1)

    def mix_values(self, p, v):
        pe = IntervalTensor(p.lo[..., :, :, None], p.hi[..., :, :, None])
        ve = IntervalTensor(v.lo[..., None, :, :], v.hi[..., None, :, :])
        shp = np.broadcast_shapes(pe.shape, ve.shape)
        self._op = "mix_values"
        try:
            prod = self.mul(broadcast_interval(pe, shp),
                            broadcast_interval(ve, shp))
        finally:
            self._op = None
        return self.sum(prod, axis=-2)

    # ---- cost attribution ----------------------------------------------
    @contextlib.contextmanager
    def scope(self, name: str):
        prev = self._scope
        self._scope = name
        with self.ctx.scope(name):
            try:
                yield self
            finally:
                self._scope = prev
