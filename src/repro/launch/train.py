"""Training launcher: mesh setup + sharded train loop.

    python -m repro.launch.train --arch smollm-135m --steps 200 \
        --data-parallel 1 --model-parallel 1 --batch 8 --seq 128

On a single CPU host this runs a reduced config end-to-end (real training,
loss must fall); on TPU pods the same entry point builds the production
mesh and shards state via the same rules the dry-run compiles (the dry-run
IS this launcher's compile path).  Fault tolerance: auto-resume from the
newest committed checkpoint + restart supervision (distributed.fault).
"""

from __future__ import annotations

import argparse
import logging

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--attention", default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the reduced per-family config (CPU scale)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    log = logging.getLogger("repro.launch.train")

    from repro.checkpoint import CheckpointConfig
    from repro.configs import get_config
    from repro.data.pipeline import PipelineConfig, lm_batch_at
    from repro.distributed.fault import SupervisorConfig, run_supervised
    from repro.distributed.sharding import use_mesh
    from repro.launch.mesh import make_mesh
    from repro.models.registry import get_model
    from repro.optim import AdamWConfig, warmup_cosine
    from repro.train.loop import TrainConfig, train

    name = args.arch if not args.attention else f"{args.arch}@{args.attention}"
    cfg = get_config(name)
    if args.reduced:
        cfg = cfg.reduced()
    api = get_model(cfg)

    pipe = PipelineConfig(global_batch=args.batch, seq_len=args.seq,
                          vocab_size=cfg.vocab_size, seed=args.seed)
    opt_cfg = AdamWConfig(lr=warmup_cosine(args.lr, 10, args.steps))
    train_cfg = TrainConfig(
        total_steps=args.steps, seed=args.seed,
        checkpoint=(CheckpointConfig(args.ckpt_dir,
                                     every_steps=args.ckpt_every)
                    if args.ckpt_dir else None))

    def batch_fn(step):
        return lm_batch_at(pipe, step)

    dp, mp = args.data_parallel, args.model_parallel
    n_dev = len(jax.devices())
    if dp * mp > n_dev:
        raise SystemExit(f"mesh {dp}x{mp} needs {dp*mp} devices, "
                         f"have {n_dev}")

    result = {}

    def run(attempt):
        log.info("attempt %d: training %s for %d steps on %dx%d mesh",
                 attempt, cfg.name, args.steps, dp, mp)
        if dp * mp > 1:
            mesh = make_mesh(dp, mp)
            with use_mesh(mesh):
                result.update(train(api, opt_cfg, train_cfg, batch_fn))
        else:
            result.update(train(api, opt_cfg, train_cfg, batch_fn))

    run_supervised(run, SupervisorConfig(max_restarts=args.max_restarts))
    hist = result["history"]
    if hist:
        log.info("final loss %.4f (first %.4f)", hist[-1]["loss"],
                 hist[0]["loss"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
