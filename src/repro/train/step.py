"""Train / serve step builders — the functions pjit compiles.

``make_train_step(api, opt_cfg)`` returns a pure
``(params, opt_state, batch) -> (params', opt_state', metrics)`` suitable
for ``jax.jit`` with in/out shardings from :mod:`repro.distributed.sharding`.

Cross-entropy notes at production vocab sizes (152k–202k): logits stay in
the compute dtype and are TP-sharded over the vocab axis; the log-sum-exp
reduction crosses the ``model`` axis as a cheap scalar all-reduce instead
of materializing fp32 logits (b·s·V fp32 would be tens of GB per shard).
Label positions < 0 are masked out of the loss (padding / image tokens).
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.models.registry import ModelApi
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw


import functools


@jax.custom_vjp
def _token_nll(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-token negative log likelihood, memory-lean.

    Autodiff of logsumexp+gather saves an fp32 softmax residual the size of
    the logits (GBs per chip at 150k–200k vocab).  This custom VJP saves
    only the compute-dtype logits + the (b, s) fp32 lse; both forward
    reductions and the backward ``exp(x − lse) − onehot`` are elementwise/
    reduce fusions, so no fp32 logits-sized buffer ever materializes.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return lse - gold


def _token_nll_fwd(logits, labels):
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return lse - gold, (logits, labels, lse)


def _token_nll_bwd(res, g):
    logits, labels, lse = res
    # softmax·g, fused exp->cast (no fp32 logits-size buffer); the −onehot·g
    # term is a scatter-add at the gold indices (a one_hot here would
    # materialize a (b, s, V) fp32 buffer)
    grad = (jnp.exp(logits.astype(jnp.float32) - lse[..., None])
            * g[..., None]).astype(logits.dtype)
    b, s = labels.shape
    bi = jnp.arange(b)[:, None]
    si = jnp.arange(s)[None, :]
    grad = grad.at[bi, si, labels].add(-g.astype(grad.dtype))
    return grad, jnp.zeros(labels.shape, jax.dtypes.float0)


_token_nll.defvjp(_token_nll_fwd, _token_nll_bwd)


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean masked cross-entropy. logits (b, s, V); labels (b, s) int32,
    negative = ignore."""
    mask = (labels >= 0)
    safe = jnp.maximum(labels, 0)
    nll = _token_nll(logits, safe) * mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1)
    return jnp.sum(nll) / denom


def _align_labels(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Pad/crop labels on the sequence axis to the logits length (VLM
    prepends image positions: those get ignore-labels)."""
    s_logits = logits.shape[1]
    s_labels = labels.shape[1]
    if s_labels == s_logits:
        return labels
    if s_labels < s_logits:
        pad = jnp.full((labels.shape[0], s_logits - s_labels), -1,
                       labels.dtype)
        return jnp.concatenate([pad, labels], axis=1)
    return labels[:, -s_logits:]


def make_loss_fn(api: ModelApi) -> Callable:
    cfg = api.cfg

    def loss_fn(params, batch) -> tuple:
        logits, aux = api.forward(params, batch)
        loss = softmax_xent(logits, _align_labels(logits, batch["labels"]))
        metrics = {"xent": loss}
        if cfg.moe is not None:
            lb, zl = aux[0], aux[1]
            loss = (loss + cfg.moe.lb_loss_weight * lb
                    + cfg.moe.z_loss_weight * zl)
            metrics["moe_lb"] = lb
            metrics["moe_z"] = zl
        metrics["loss"] = loss
        return loss, metrics

    return loss_fn


def make_train_step(api: ModelApi, opt_cfg: AdamWConfig,
                    microbatches: int = 1) -> Callable:
    """Build the jitted train step.

    ``microbatches`` > 1 splits the global batch into N sequential
    micro-steps with fp32 gradient accumulation — the standard production
    lever for activation memory (peak activations scale ~1/N; the optimizer
    update runs once on the mean gradient, so training semantics are
    unchanged up to loss-mean weighting across equal-sized microbatches).
    """
    loss_fn = make_loss_fn(api)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def mb_body(acc, mb):
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g)
                return acc, m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(mb_body, zeros, split)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, opt_cfg)
        return new_params, new_opt, {**metrics, **opt_metrics}

    return train_step


def make_eval_step(api: ModelApi) -> Callable:
    loss_fn = make_loss_fn(api)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step


def make_prefill_step(api: ModelApi, batch_chunks: int = 8) -> Callable:
    """Serving prefill: returns the LAST position's logits only (the decode
    bootstrap) and maps the forward over batch chunks — full-sequence
    logits for a 32k-token prefill batch would be tens of GB per chip with
    no consumer, and chunking bounds activation peaks the same way
    microbatching does for training."""

    def prefill_step(params, batch):
        from repro.distributed.sharding import current_mesh

        b = next(iter(batch.values())).shape[0]
        # per-chunk batch must stay divisible by the DP degree, or SPMD
        # replicates the chunk across the data axis (16x memory)
        mesh = current_mesh()
        dp = 1
        if mesh is not None:
            for ax in ("pod", "data"):
                if ax in mesh.axis_names:
                    dp *= mesh.shape[ax]
        n = max(1, min(batch_chunks, b // dp))
        while b % n or (b // n) % dp:
            n -= 1

        if n <= 1:
            logits, _ = api.forward(params, batch)
            return logits[:, -1:]

        split = jax.tree.map(
            lambda x: x.reshape((n, b // n) + x.shape[1:]), batch)

        def one(chunk):
            logits, _ = api.forward(params, chunk)
            return logits[:, -1:]

        out = jax.lax.map(one, split)
        return out.reshape((b, 1) + out.shape[3:])

    return prefill_step


def make_serve_step(api: ModelApi) -> Callable:
    """One decode step: greedy next token against the KV cache/state."""

    def serve_step(params, tokens, states, batch):
        logits, new_states = api.step(params, tokens, states, batch)
        next_tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tokens, logits, new_states

    return serve_step


def init_train_state(api: ModelApi, opt_cfg: AdamWConfig, key):
    """Initialize (params, opt_state) — unboxed arrays + axes tree."""
    from repro.nn.module import axes_of, unbox

    boxed = api.init(key)
    params = unbox(boxed)
    axes = axes_of(boxed)
    opt_state = init_adamw(params, opt_cfg)
    return params, opt_state, axes
