"""Both attention mechanisms as TFHE circuits over :class:`EncTensor`.

These are the encrypted counterparts of the paper's scaling experiment
(single head, embedding dim ≤ 4, integers up to 8-bit) and of
:mod:`repro.quant.int_attention`.  Each returns the exact integer result
plus the per-circuit cost summary used by Tables 2 and 4.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.fhe.tfhe_sim import EncTensor, FheContext, encrypt


def inhibitor_attention_circuit(
    q: np.ndarray,     # (T, d) int
    k: np.ndarray,     # (T, d) int
    v: np.ndarray,     # (T, d) int
    *,
    gamma_shift: int = 0,
    alpha_q: int = 0,
    ctx: Optional[FheContext] = None,
) -> Tuple[np.ndarray, dict]:
    """Encrypted Inhibitor attention (paper eq. 5 + 6, integer form).

    PBS inventory per (T, d) single head:
      * scores:     T²·d  abs-LUTs  (+ T² shift-ReLU LUTs when α > 0)
      * inhibition: T²·d  ReLU-LUTs
    No ciphertext multiplications at all — additions are levelled.
    """
    ctx = ctx or FheContext()
    eq, _ = encrypt(q, ctx)
    ek, _ = encrypt(k, ctx)
    ev, _ = encrypt(v, ctx)
    T, d = q.shape

    # Z[i,j] = Σ_k |q_ik − k_jk|  >> gamma_shift
    diff = EncTensor(eq.values[:, None, :] - ek.values[None, :, :], ctx)
    ctx.count_add(diff.values)
    z = diff.abs().sum(axis=-1)
    if gamma_shift:
        z = z.shift_right(gamma_shift)
    if alpha_q:
        z = (z - alpha_q).relu()

    # H[i,k] = Σ_j relu(V[j,k] − Z[i,j])
    spread = EncTensor(ev.values[None, :, :] - z.values[:, :, None], ctx)
    ctx.count_add(spread.values)
    h = spread.relu().sum(axis=1)
    return h.values, ctx.summary()


def dotprod_attention_circuit(
    q: np.ndarray,
    k: np.ndarray,
    v: np.ndarray,
    *,
    scale_shift: int = 0,
    softmax_frac_bits: int = 4,
    ctx: Optional[FheContext] = None,
) -> Tuple[np.ndarray, dict]:
    """Encrypted dot-product attention (paper's baseline arm).

    PBS inventory per (T, d) single head:
      * QKᵀ:      2·T²·d  (cipher muls, 2 PBS each)
      * softmax:  T²  exp-LUTs + T² cipher muls with the reciprocal
                  (2 PBS each) + T reciprocal LUTs
      * S·V:      2·T²·d  (cipher muls)
    ≈ 4·T²·d + 3·T² PBS — about twice the inhibitor, with wider messages
    (the products' a±b PBS inputs add ~1 bit; accumulated scores add more).
    """
    ctx = ctx or FheContext()
    eq, _ = encrypt(q, ctx)
    ek, _ = encrypt(k, ctx)
    ev, _ = encrypt(v, ctx)
    T, d = q.shape

    # scores: S[i,j] = Σ_k q_ik · k_jk  (cipher×cipher)
    qe = EncTensor(np.broadcast_to(eq.values[:, None, :], (T, T, d)).copy(),
                   ctx)
    ke = EncTensor(np.broadcast_to(ek.values[None, :, :], (T, T, d)).copy(),
                   ctx)
    s = qe.mul_cipher(ke).sum(axis=-1)
    if scale_shift:
        s = s.shift_right(scale_shift)

    # integer softmax surrogate: max-shifted exp2 LUT, fixed-point.
    # The exp window is clipped to [-15, 0]: deeper scores quantize to 0
    # probability anyway at 4 fractional bits (paper-scale message spaces).
    m = s.values.max(axis=-1, keepdims=True)       # max tree: b + relu(a−b),
    ctx.count_pbs(s.values)                        # ~1 PBS per element
    dshift = np.clip(s.values - m, -15, 0)
    ctx.count_add(dshift)
    p = EncTensor(dshift, ctx).lut(
        lambda x: (np.exp2(np.maximum(x, -15).astype(np.float64))
                   * (1 << softmax_frac_bits)).astype(np.int64))
    denom = p.sum(axis=-1)
    # reciprocal LUT of the row sum, then cipher multiply
    recip = denom.lut(
        lambda x: ((1 << (2 * softmax_frac_bits))
                   // np.maximum(x, 1)).astype(np.int64))
    pr = p.mul_cipher(EncTensor(
        np.broadcast_to(recip.values[:, None], p.values.shape).copy(), ctx))
    pr = pr.shift_right(softmax_frac_bits)

    # H = S·V (cipher×cipher) with fixed-point renormalization
    pe = EncTensor(np.broadcast_to(pr.values[:, :, None], (T, T, d)).copy(),
                   ctx)
    ve = EncTensor(np.broadcast_to(ev.values[None, :, :], (T, T, d)).copy(),
                   ctx)
    h = pe.mul_cipher(ve).sum(axis=1).shift_right(softmax_frac_bits)
    return h.values, ctx.summary()
