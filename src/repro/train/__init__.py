"""Training: step builders + loop."""

from repro.train.loop import TrainConfig, train  # noqa: F401
from repro.train.step import (  # noqa: F401
    init_train_state,
    make_eval_step,
    make_loss_fn,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    softmax_xent,
)
