"""Optimizers, schedules, gradient compression."""

from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    AdamWState,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_adamw,
)
from repro.optim.compress import (  # noqa: F401
    CompressionState,
    compress_tree,
    decompress_tree,
    init_compression,
)
from repro.optim.schedule import constant, warmup_cosine, warmup_linear  # noqa: F401
