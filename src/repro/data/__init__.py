"""Data: synthetic paper tasks + sharded deterministic pipeline."""

from repro.data.pipeline import PipelineConfig, Prefetcher, lm_batch_at  # noqa: F401
from repro.data.synthetic import (  # noqa: F401
    adding_problem,
    copy_words,
    digits,
    lm_tokens,
    sentiment,
)
