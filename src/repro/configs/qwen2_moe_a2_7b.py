"""qwen2-moe-a2.7b (Qwen1.5-MoE-A2.7B) — 60 routed experts top-4 + 4 shared.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
24L d_model=2048 16H (kv=16) d_ff=1408 (per expert) vocab=151936,
head_dim=128, shared-expert hidden 4×1408=5632 with sigmoid gate.

The expert axis is padded 60 -> 64 for even expert-parallel sharding over
the 16-way model axis (padding experts get ~0 router probability at init
and are never selected by top-k thereafter; they cost capacity-buffer FLOPs
only — recorded in DESIGN.md).
"""

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.attention import AttentionConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    d_ff=1408,
    vocab_size=151936,
    attention=AttentionConfig(
        mechanism="dotprod", num_heads=16, num_kv_heads=16, head_dim=128,
        qkv_bias=True, use_rope=True, rope_base=1000000.0, causal=True),
    norm="rmsnorm",
    norm_eps=1e-6,
    mlp="gated_silu",
    moe=MoEConfig(
        num_experts=60, top_k=4, expert_hidden_dim=1408,
        shared_hidden_dim=5632, shared_gate=True,
        normalize_topk=False, capacity_factor=1.25, padded_experts=64),
    tie_embeddings=False,
    max_seq_len=32768,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
