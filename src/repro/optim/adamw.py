"""AdamW optimizer (pure JAX, optax-free) with sharding-aware state.

Optimizer state mirrors the parameter tree (same structure, same logical
axes), so FSDP sharding of parameters automatically shards moments — the
ZeRO-style memory split falls out of the rules table for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0
    # moments dtype: fp32 is the safe default; bf16 halves optimizer memory
    moment_dtype: str = "float32"


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_adamw(params, cfg: AdamWConfig) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, mdt if jnp.issubdtype(
            x.dtype, jnp.floating) else x.dtype), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def _lr_at(cfg: AdamWConfig, step):
    if callable(cfg.lr):
        return cfg.lr(step)
    return cfg.lr


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    metrics = {}
    if cfg.grad_clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
        metrics["grad_norm"] = gnorm
    step = state.step + 1
    lr = _lr_at(cfg, step)
    metrics["lr"] = lr
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, n, p):
        gf = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        n32 = n.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        mhat = m32 / bc1
        nhat = n32 / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return (new_p.astype(p.dtype), m32.astype(m.dtype),
                n32.astype(n.dtype))

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), metrics
