"""Architecture registry: ``--arch <id>`` resolution.

Each assigned architecture lives in its own module with the exact published
config; ``get_config(name)`` resolves ids (with or without the attention
override suffix ``@inhibitor`` / ``@inhibitor_unsigned`` / ``@dotprod``).
"""

from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, EncDecConfig, FrontendConfig,
    ShapeConfig, SHAPES, SHAPES_BY_NAME)

_MODULES = {
    "smollm-135m": "repro.configs.smollm_135m",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_16e",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "paper-tiny": "repro.configs.paper_tiny",
}

ARCH_IDS = tuple(k for k in _MODULES if k != "paper-tiny")

# archs whose attention is replaceable by the paper's mechanism
INHIBITOR_APPLICABLE = tuple(a for a in ARCH_IDS if a != "rwkv6-7b")

# sub-quadratic archs eligible for the long_500k shape (DESIGN.md §5)
LONG_CONTEXT_ARCHS = ("rwkv6-7b", "hymba-1.5b")


def get_config(name: str) -> ModelConfig:
    """Resolve an arch id, optionally suffixed ``@<attention_kind>``."""
    import importlib

    kind = None
    if "@" in name:
        name, kind = name.split("@", 1)
    if name not in _MODULES:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    cfg = importlib.import_module(_MODULES[name]).CONFIG
    if kind is not None:
        if name == "rwkv6-7b" and kind != "dotprod":
            raise ValueError(
                "rwkv6-7b is attention-free; the inhibitor mechanism is "
                "inapplicable (DESIGN.md §Arch-applicability)")
        cfg = cfg.with_attention_kind(kind)
    return cfg
